"""Observability layer: metrics registry, tracer, exporter, stats clocks.

Three groups:

* in-process unit tests for the unified registry (catalog enforcement),
  the tracer (deterministic sampling, ring bound, nesting/attach), the
  exporter JSONL/Prometheus round trip, and ``ServingStats`` time
  semantics under an injected clock (exact window boundaries, reservoir
  ring wraparound, single-event rates, padding efficiency);
* invariant-8 checks: sampling 0 is bit-identical to an untraced run,
  and the deep-traced **staged** engine returns bit-identical results to
  the fused path (unsharded here; the sharded variant runs in a
  subprocess below and in tests/test_crash_recovery.py's harness);
* subprocess acceptance tests on an 8-device host mesh: one sampled query
  yields a single trace covering admission -> embed -> hash -> probe ->
  gather -> rerank -> merge -> fanin with stage spans summing to >= 90%
  of the batch span, and a kill -9 crash + recover() yields
  ``recover.restore`` / ``recover.replay`` spans plus recovery metrics.
"""

import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.index import IndexConfig
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.obs.metrics import CATALOG, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve.batcher import MicroBatcher
from repro.serve.segments import SegmentedIndex
from repro.serve.stats import ServingStats

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env(n_devices=1):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={n_devices}")
    return env


def _run(code: str, n_devices=1, timeout=560):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=_env(n_devices))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.inc("serve_queries_total", 3, tenant="t")
    reg.inc("serve_queries_total", 2, tenant="t")
    reg.set("serve_recall_proxy", 0.75, tenant="t")
    reg.observe("serve_query_latency_s", 0.005, tenant="t")
    reg.observe("serve_query_latency_s", 2.0, tenant="t")
    assert reg.value("serve_queries_total", tenant="t") == 5
    assert reg.value("serve_recall_proxy", tenant="t") == 0.75
    h = reg.value("serve_query_latency_s", tenant="t")
    assert h["count"] == 2 and abs(h["sum"] - 2.005) < 1e-9
    # cumulative buckets end at +Inf == count
    assert h["buckets"][-1] == ["+Inf", 2]
    # collect() is export-shaped: name/type/labels per entry
    entries = {e["name"]: e for e in reg.collect()}
    assert entries["serve_queries_total"]["labels"] == {"tenant": "t"}
    assert entries["serve_query_latency_s"]["type"] == "histogram"


def test_registry_rejects_schema_drift():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.inc("not_a_documented_metric", tenant="t")
    with pytest.raises(ValueError):
        reg.inc("serve_queries_total", shard="0")      # wrong label key
    with pytest.raises(ValueError):
        reg.inc("serve_queries_total")                 # missing tenant
    with pytest.raises(TypeError):
        reg.set("serve_queries_total", 1.0, tenant="t")  # counter, not gauge


def test_registry_summary_filters_by_label():
    reg = MetricsRegistry()
    reg.inc("serve_queries_total", 7, tenant="a")
    reg.inc("serve_queries_total", 9, tenant="b")
    reg.inc("serve_segment_wins_total", 4, tenant="a", segment="2")
    s = reg.summary(tenant="a")
    assert s["serve_queries_total"] == 7
    assert s["serve_segment_wins_total{segment=2}"] == 4
    assert not any("9" == str(v) for v in s.values())


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_sampling_is_deterministic_in_trace_id():
    a = Tracer(sample_rate=0.5, seed=1234)
    b = Tracer(sample_rate=0.5, seed=1234)
    da = [a.start_trace().sampled for _ in range(200)]
    db = [b.start_trace().sampled for _ in range(200)]
    assert da == db                       # same seed -> same decisions
    frac = sum(da) / len(da)
    assert 0.3 < frac < 0.7               # rate is actually honoured
    c = Tracer(sample_rate=0.0)
    assert c.start_trace() is None        # rate 0: no context at all


def test_span_ring_is_bounded():
    tr = Tracer(sample_rate=1.0, buffer=16)
    for i in range(50):
        with tr.span("hash", tenant="t", i=i):
            pass
    spans = tr.spans()
    assert len(spans) == 16
    assert [s["attrs"]["i"] for s in spans] == list(range(34, 50))
    assert tr.n_spans == 50               # drops are countable
    assert tr.drain() and tr.spans() == []


def test_span_nesting_and_attach():
    tr = Tracer(sample_rate=1.0)
    with tr.span("request", tenant="t") as root:
        ctx = tr.current()
        assert ctx is not None and ctx.sampled and tr.sampled()
        with tr.span("hash", tenant="t") as child:
            assert child.parent_id == root.span_id
        tr.record("admission", 1.0, 2.0, tenant="t")
    assert tr.current() is None           # root span restored the thread
    by_name = {s["name"]: s for s in tr.spans()}
    assert by_name["hash"]["parent_id"] == by_name["request"]["span_id"]
    assert by_name["admission"]["parent_id"] == by_name["request"]["span_id"]
    assert by_name["request"]["parent_id"] is None
    assert len({s["trace_id"] for s in tr.spans()}) == 1  # one trace


def test_unsampled_context_suppresses_descendants():
    tr = Tracer(sample_rate=0.5, seed=0)
    # find an unsampled decision, then check span() under it is a no-op
    for _ in range(100):
        ctx = tr.start_trace()
        if not ctx.sampled:
            break
    assert not ctx.sampled
    with tr.attach(ctx):
        assert tr.span("hash", tenant="t") is obs_trace._NOOP
    assert tr.spans() == []


def test_stage_spans_feed_latency_histogram():
    reg = MetricsRegistry()
    tr = Tracer(sample_rate=1.0, metrics=reg)
    with tr.span("gather", tenant="t"):
        pass
    with tr.span("not_a_stage", tenant="t"):
        pass
    h = reg.value("serve_stage_latency_s", tenant="t", stage="gather")
    assert h["count"] == 1
    assert reg.value("serve_stage_latency_s", tenant="t",
                     stage="not_a_stage") is None


# ---------------------------------------------------------------------------
# ServingStats time semantics (injected clock)
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _stats(clock, **kw):
    return ServingStats(clock=clock, tenant="t",
                        metrics=MetricsRegistry(), **kw)


def test_window_trim_at_exact_boundary():
    clock = _Clock()
    st = _stats(clock, window_s=10.0)
    st.record_query(4)                       # event at t=0
    clock.t = 10.0                           # exactly window edge
    # trim drops strictly-older events: t=0 is NOT < 10 - 10, so it stays
    assert st.qps() == pytest.approx(4 / 10.0)
    clock.t = 10.0 + 1e-6                    # one tick past the edge
    assert st.qps() == 0.0


def test_latency_reservoir_wraps_as_a_ring():
    clock = _Clock()
    st = _stats(clock, reservoir=8)
    for i in range(1, 21):                   # 20 > 8: ring wraps twice
        st.record_query(1, latency_s=float(i))
    assert st._lat_n == 20
    p = st.latency_percentiles()
    # only the last 8 observations (13..20 s) survive the wraparound
    assert p["p50_ms"] == pytest.approx(
        float(np.percentile(np.arange(13, 21) * 1e3, 50)))
    assert p["p99_ms"] <= 20_000.0 and p["p50_ms"] >= 13_000.0


def test_rate_with_single_event():
    clock = _Clock()
    st = _stats(clock)
    clock.t = 5.0
    st.record_query(6)
    # now == the only event's timestamp: span clamps to 1e-9, rate is
    # finite (never a ZeroDivisionError)
    assert np.isfinite(st.qps()) and st.qps() > 0
    clock.t = 8.0
    assert st.qps() == pytest.approx(6 / 3.0)
    st2 = _stats(clock)
    assert st2.qps() == 0.0                  # no events at all


def test_padding_efficiency_tracks_fill_rows():
    clock = _Clock()
    st = _stats(clock)
    assert st.padding_efficiency() == 1.0    # no batches yet
    st.record_batch(30, 32, 0.01)
    st.record_batch(16, 32, 0.01)
    assert st.padding_efficiency() == pytest.approx(46 / 64)
    snap = st.snapshot()
    assert snap["padding_efficiency"] == pytest.approx(0.7188, abs=1e-4)
    assert snap["recall_proxy"] is None
    st.record_recall(0.9)
    assert st.snapshot()["recall_proxy"] == 0.9
    # the registry saw pad-fill rows only, not the chunk totals
    assert st.metrics.value("serve_batch_rows_real_total",
                            tenant="t") == 46
    assert st.metrics.value("serve_batch_rows_padded_total",
                            tenant="t") == 18


def test_queue_wait_histogram_from_batcher():
    clock = _Clock()
    reg = MetricsRegistry()
    calls = []

    def qfn(q, k, npb):
        calls.append(q.shape)
        return (np.zeros((q.shape[0], k), np.int32),
                np.zeros((q.shape[0], k), np.float32))

    b = MicroBatcher(qfn, chunk_sizes=(8,), max_delay_ms=5.0, clock=clock,
                     tenant="t", metrics=reg)
    b.submit(np.zeros((3, 4), np.float32), k=2)
    clock.t = 0.25                           # request waited 250 ms
    b.flush_all()
    h = reg.value("serve_queue_wait_s", tenant="t")
    assert h["count"] == 1
    assert h["sum"] == pytest.approx(0.25)
    assert calls == [(8, 4)]


# ---------------------------------------------------------------------------
# invariant 8: tracing is invisible
# ---------------------------------------------------------------------------


def _small_index(seed=0):
    cfg = IndexConfig(n_dims=16, n_tables=4, n_hashes=4, log2_buckets=8,
                      bucket_capacity=32, r=4.0)
    idx = SegmentedIndex(cfg, segment_capacity=64, insert_chunk=32,
                         seed=seed)
    rng = np.random.default_rng(seed)
    g = idx.insert(rng.normal(size=(150, 16)).astype(np.float32))
    idx.delete(g[::7])
    return idx, rng


def test_rate0_bit_identical_and_span_free():
    idx, rng = _small_index()
    q = rng.normal(size=(8, 16)).astype(np.float32)
    base_g, base_d = map(np.asarray, idx.query(q, 5, n_probes=3))
    tr = obs_trace.tracer()
    tr.drain()
    before = tr.n_spans
    try:
        obs_trace.configure(sample_rate=0.0, deep=True)
        g, d = map(np.asarray, idx.query(q, 5, n_probes=3))
    finally:
        obs_trace.configure(sample_rate=0.0, deep=False)
    np.testing.assert_array_equal(base_g, g)
    np.testing.assert_array_equal(base_d, d)
    assert tr.n_spans == before              # not one span was recorded


def test_deep_staged_query_bit_identical_to_fused():
    idx, rng = _small_index(seed=3)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    base_g, base_d = map(np.asarray, idx.query(q, 5, n_probes=3))
    tr = obs_trace.tracer()
    tr.drain()
    try:
        obs_trace.configure(sample_rate=1.0, deep=True)
        # the staged engine only runs inside a sampled trace (the batcher's
        # batch span provides one in production)
        with tr.span("request", tenant="t"):
            g, d = map(np.asarray, idx.query(q, 5, n_probes=3))
    finally:
        obs_trace.configure(sample_rate=0.0, deep=False)
        names = {s["name"] for s in tr.drain()}
    np.testing.assert_array_equal(base_g, g)
    np.testing.assert_array_equal(base_d, d)
    # the staged engine actually ran, stage by stage
    assert {"hash", "probe", "gather", "rerank", "merge"} <= names


# ---------------------------------------------------------------------------
# exporter round trip
# ---------------------------------------------------------------------------


def test_exporter_jsonl_and_prometheus(tmp_path):
    reg = MetricsRegistry()
    tr = Tracer(sample_rate=1.0, metrics=reg)
    reg.inc("serve_queries_total", 12, tenant="t")
    reg.observe("wal_fsync_latency_s", 0.002, tenant="t")
    with tr.span("hash", tenant="t"):
        pass
    exp = obs_export.Exporter(str(tmp_path / "metrics.jsonl"),
                              registry=reg, tracer=tr,
                              prom_path=str(tmp_path / "metrics.prom"))
    n = exp.flush()
    assert n >= 4                 # 2 metric series (one is a stage
    #                               histogram from the span) + 1 span
    lines = [json.loads(x) for x in
             (tmp_path / "metrics.jsonl").read_text().splitlines()]
    metrics = [o for o in lines if o["kind"] == "metric"]
    spans = [o for o in lines if o["kind"] == "span"]
    assert len({o["ts"] for o in metrics}) == 1   # one shared snapshot ts
    for o in metrics:                             # schema-is-code contract
        spec = CATALOG[o["name"]]
        assert o["type"] == spec.type
        assert sorted(o["labels"]) == sorted(spec.labels)
    assert spans and spans[0]["name"] == "hash"
    assert spans[0]["t1"] >= spans[0]["t0"]
    # drained: a second flush re-snapshots metrics but not old spans
    exp.flush()
    again = [json.loads(x) for x in
             (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert sum(o["kind"] == "span" for o in again) == 1
    prom = (tmp_path / "metrics.prom").read_text()
    assert 'serve_queries_total{tenant="t"} 12' in prom
    assert "# TYPE wal_fsync_latency_s histogram" in prom
    assert 'wal_fsync_latency_s_count{tenant="t"} 1' in prom
    exp.close()


def test_export_checker_tool_rejects_drift(tmp_path):
    """The CI drift gate really fails on an undocumented metric name."""
    good = {"kind": "metric", "ts": 1.0, "name": "serve_queries_total",
            "type": "counter", "labels": {"tenant": "t"}, "value": 5}
    bad = dict(good, name="serve_undocumented_total")
    p = tmp_path / "metrics.jsonl"
    p.write_text(json.dumps(good) + "\n" + json.dumps(bad) + "\n")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools",
                                      "check_metrics_export.py"),
         str(tmp_path), "--no-spans"],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1
    assert "undocumented metric" in proc.stderr


# ---------------------------------------------------------------------------
# acceptance: one sampled query on the 8-device sharded path
# ---------------------------------------------------------------------------


def test_sharded_deep_trace_covers_every_stage():
    code = """
        import numpy as np
        from repro.launch.mesh import make_serve_mesh
        from repro.obs import trace as obs_trace
        from repro.serve import ServableRegistry, ServableSpec

        mesh = make_serve_mesh(8)
        reg = ServableRegistry(mesh=mesh)
        sv = reg.register(ServableSpec(
            name="t8", n_dims=16, r=2.0, log2_buckets=8, bucket_capacity=64,
            segment_capacity=64, insert_chunk=32, chunk_sizes=(128,),
            max_delay_ms=1.0, shard_axis="serve"))
        rng = np.random.default_rng(0)
        for _ in range(6):                       # several sealed segments
            sv.insert(rng.normal(size=(64, 16)).astype(np.float32))

        fv = rng.normal(size=(128, len(sv.nodes())))
        # untraced baseline over the SAME queries (fused collective)
        q_base = np.asarray(sv.embed(fv))
        base_g, base_d = map(np.asarray, sv.index.query(q_base, 10,
                                                        n_probes=3))

        tr = obs_trace.configure(sample_rate=1.0, deep=True)
        tr.drain()
        with tr.span("request", tenant="t8"):    # one trace for everything
            q = np.asarray(sv.embed(fv))
            fut = sv.submit_query(q, 10, n_probes=3)
            sv.batcher.flush_all()
            g, d = fut.result()
        obs_trace.configure(sample_rate=0.0, deep=False)

        np.testing.assert_array_equal(base_g, np.asarray(g))
        np.testing.assert_array_equal(base_d, np.asarray(d))

        spans = tr.drain()
        assert len({s["trace_id"] for s in spans}) == 1, "one trace"
        by = {}
        for s in spans:
            by.setdefault(s["name"], []).append(s)
        for name in ("request", "admission", "embed", "batch", "hash",
                     "probe", "gather", "rerank", "merge", "fanin"):
            assert name in by, f"missing span {name}: {sorted(by)}"
        root = by["request"][0]
        sid = {s["span_id"]: s for ss in by.values() for s in ss}
        # every span is a descendant of the request root
        for s in spans:
            p = s
            while p["parent_id"] is not None:
                p = sid[p["parent_id"]]
            assert p is root
        batch = by["batch"][0]
        stages = [s for n in ("hash", "probe", "gather", "rerank",
                              "merge", "fanin") for s in by[n]]
        stage_s = sum(s["t1"] - s["t0"] for s in stages)
        batch_s = batch["t1"] - batch["t0"]
        frac = stage_s / batch_s
        assert frac >= 0.90, f"stage spans cover {frac:.1%} of batch"
        print(f"OK frac={frac:.3f}")
    """
    proc = _run(code, n_devices=8)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_kill9_recovery_emits_recovery_spans(tmp_path):
    wal = str(tmp_path / "wal")
    snap = str(tmp_path / "snap")
    crash = f"""
        import numpy as np
        from repro.serve import ServableRegistry, ServableSpec, faults
        reg = ServableRegistry(wal_dir={wal!r}, fsync_every=2)
        sv = reg.register(ServableSpec(
            name="t", n_dims=16, r=2.0, log2_buckets=8, bucket_capacity=64,
            segment_capacity=64, insert_chunk=32, chunk_sizes=(8, 32)))
        rng = np.random.default_rng(0)
        for _ in range(3):
            sv.insert(rng.normal(size=(40, 16)).astype(np.float32))
        reg.snapshot({snap!r}, step=1)
        faults.install(faults.FaultPlan(("wal.append", 3, "kill")))
        for _ in range(8):
            sv.insert(rng.normal(size=(40, 16)).astype(np.float32))
        raise SystemExit("unreachable: the fault plan must kill us")
    """
    proc = _run(crash)
    assert proc.returncode == -signal.SIGKILL, (proc.returncode,
                                                proc.stderr)
    recover = f"""
        import numpy as np
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.serve import ServableRegistry
        tr = obs_trace.configure(sample_rate=1.0)
        tr.drain()
        reg = ServableRegistry(wal_dir={wal!r})
        reports = reg.recover(ckpt_root={snap!r}, wal_dir={wal!r})
        assert reports["t"]["restored_step"] == 1, reports
        assert reports["t"]["n_records"] > 0, reports
        names = [s["name"] for s in tr.drain()]
        assert "recover.restore" in names, names
        assert "recover.replay" in names, names
        assert "ckpt.restore" in names, names
        m = obs_metrics.registry()
        assert m.value("recovery_restores_total", tenant="t") == 1
        assert m.value("recovery_replayed_records_total", tenant="t") > 0
        assert m.value("ckpt_restores_total", tenant="t") == 1
        g, d = reg.get("t").index.query(
            np.asarray(np.random.default_rng(1).normal(size=(4, 16)),
                       np.float32), 5, n_probes=3)
        assert np.asarray(g).shape == (4, 5)
        print("OK")
    """
    proc = _run(recover)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
