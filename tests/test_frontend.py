"""Live-traffic acceptance harness for the network serving front-end.

The server runs as a **real subprocess** (``launch/serve --listen``) and
is driven by concurrent :class:`FrontendClient` connections -- genuine
wall-clock deadlines, genuine sockets, genuine signals:

* concurrent multi-tenant traffic across all three paper tenants (basis,
  QMC, Wasserstein) is answered **bit-identically** to direct library
  queries against an in-process registry built from the same
  ``default_specs`` and the same insert order (invariant 9: the network
  layer is invisible);
* under overload (tiny quotas, many clients) the server answers with
  explicit backpressure -- nonzero structured rejects carrying
  ``retry_after_ms``, queue depth bounded by admission -- instead of
  queueing unboundedly;
* SIGTERM drains gracefully: every *accepted* request is answered before
  exit (no stream ever sees a dropped connection mid-request; the drain
  report shows ``settled == admitted``), new requests are refused with
  ``shutting_down``, and the process exits 0;
* tenant lifecycle over the wire: ``load`` a fourth tenant, serve it,
  ``unload`` it (drained, WAL-audited), after which it rejects as
  ``unknown_tenant``.

The server subprocess pins one CPU device; the comparison registry runs
in the pytest process on either CI matrix leg (tenants are unsharded, so
results are device-count independent).
"""

import dataclasses
import os
import re
import signal
import subprocess
import sys
import threading
import time

import numpy as np

from repro.launch.serve import default_specs
from repro.serve import ServableRegistry
from repro.serve.client import FrontendClient, wait_ready

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HOST = "127.0.0.1"
N_DIMS = 16
SEG_CAP = 256
TENANTS = ("l1-qmc", "l2-basis", "w2-quantile")


def _env():
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    return env


class _Server:
    """One ``launch/serve --listen`` subprocess, port parsed from stdout."""

    def __init__(self, *extra, timeout_s=120):
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve",
             "--listen", f"{HOST}:0", "--n-dims", str(N_DIMS),
             "--segment-capacity", str(SEG_CAP), *extra],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True, env=_env())
        self.lines = []
        self._reader = threading.Thread(target=self._read, daemon=True)
        self._reader.start()
        self.port = self._wait_port(timeout_s)
        wait_ready(HOST, self.port, timeout_s=timeout_s)

    def _read(self):
        for line in self.proc.stdout:
            self.lines.append(line.rstrip("\n"))

    def _wait_port(self, timeout_s):
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            for ln in list(self.lines):
                m = re.search(r"listening on [\d.]+:(\d+)", ln)
                if m:
                    return int(m.group(1))
            if self.proc.poll() is not None:
                raise RuntimeError("server died during startup:\n"
                                   + self.proc.stderr.read())
            time.sleep(0.05)
        raise TimeoutError("no '[frontend] listening on' line in "
                           f"{timeout_s}s; got {self.lines}")

    def client(self, timeout_s=60.0) -> FrontendClient:
        return FrontendClient(HOST, self.port, timeout_s=timeout_s)

    def stop(self, timeout_s=60) -> int:
        """SIGTERM (if still alive) + wait; returns the exit code."""
        if self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)
        try:
            rc = self.proc.wait(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait(timeout=10)
            raise
        self._reader.join(timeout=5)
        return rc


def _corpora(seed=7, n=48):
    rng = np.random.default_rng(seed)
    return {t: rng.normal(size=(n, N_DIMS)).astype(np.float32)
            for t in TENANTS}


def test_live_multitenant_parity_and_lifecycle():
    srv = _Server()
    try:
        corpora = _corpora()
        # sequential inserts per tenant (one client) -> deterministic gid
        # order, the precondition for bitwise parity with the direct build
        with srv.client() as c:
            gids = {t: c.insert(t, corpora[t]) for t in TENANTS}
        for t in TENANTS:
            assert gids[t].tolist() == list(range(48))

        # concurrent query phase: two client threads per tenant, mixed
        # batch sizes, so the batcher coalesces across connections
        qrng = np.random.default_rng(11)
        slices = ([0, 1, 2], [5, 6, 7, 8, 9], list(range(17, 25)))
        qsets = {t: [corpora[t][s] + qrng.normal(
                        scale=0.05, size=(len(s), N_DIMS)).astype(np.float32)
                     for s in slices] for t in TENANTS}
        results, errors = {}, []

        def run(tenant, worker):
            try:
                with srv.client() as c:
                    for qi, q in enumerate(qsets[tenant]):
                        results[(tenant, worker, qi)] = c.query_arrays(
                            tenant, q, k=5, n_probes=2)
            except Exception as e:           # noqa: BLE001
                errors.append(repr(e))

        threads = [threading.Thread(target=run, args=(t, w))
                   for t in TENANTS for w in range(2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120)
        assert not errors, errors
        assert len(results) == len(TENANTS) * 2 * len(slices)

        # invariant 9: wire answers == direct library answers, bitwise.
        # Same specs, same arrays, same insert order -> same index state.
        reg = ServableRegistry()
        for spec in default_specs(n_dims=N_DIMS, segment_capacity=SEG_CAP):
            reg.register(spec)
        for t in TENANTS:
            assert reg.get(t).insert(corpora[t]).tolist() == \
                gids[t].tolist()
        for (tenant, _w, qi), (ids, dists) in results.items():
            want_i, want_d = reg.get(tenant).index.query(
                qsets[tenant][qi], 5, n_probes=2)
            assert (np.asarray(want_i) == ids).all(), (tenant, qi)
            assert (np.asarray(want_d, np.float32) == dists).all(), \
                (tenant, qi)

        # health + stats endpoints surface lifecycle state, ServingStats
        # and the obs metrics catalog over the wire
        with srv.client() as c:
            h = c.health()
            assert set(h["tenants"]) == set(TENANTS)
            assert all(v["state"] == "ready"
                       for v in h["tenants"].values())
            assert h["draining"] is False
            assert h["totals"]["admitted"] >= len(results)
            st = c.stats()
            assert "frontend_requests_total" in st["catalog"]
            assert "serve_queries_total" in st["catalog"]
            for t in TENANTS:
                assert "qps" in st["report"][t]["stats"]
            assert any(k.startswith("frontend_requests_total")
                       for k in st["metrics"])

            # tenant lifecycle over the wire: load -> serve -> unload
            extra_spec = dataclasses.asdict(dataclasses.replace(
                default_specs(n_dims=N_DIMS,
                              segment_capacity=SEG_CAP)[0], name="extra"))
            assert c.load(extra_spec)["state"] == "ready"
            assert c.health()["tenants"]["extra"]["state"] == "ready"
            c.insert("extra", corpora["l2-basis"][:8])
            ids, _ = c.query_arrays("extra", corpora["l2-basis"][:3], k=2)
            assert ids.shape == (3, 2)
            r = c.unload("extra")
            assert r["state"] == "unloaded" and r["drained"] is True
            resp = c.query("extra", corpora["l2-basis"][:3], k=2)
            assert resp["ok"] is False
            assert resp["code"] == "unknown_tenant"
            assert "extra" not in c.health()["tenants"]
    finally:
        assert srv.stop() == 0


def test_backpressure_under_overload():
    """Tiny quotas + many concurrent clients -> nonzero structured
    rejects with retry_after_ms, bounded admission, and valid answers for
    everything accepted."""
    srv = _Server("--max-inflight", "4", "--queue-depth", "2",
                  "--max-delay-ms", "40")
    try:
        corpus = np.random.default_rng(0).normal(
            size=(64, N_DIMS)).astype(np.float32)
        with srv.client() as c:
            c.insert("l2-basis", corpus)
            c.query_arrays("l2-basis", corpus[:8], k=3)   # warm the jit

        oks, rejects = [], []
        lock = threading.Lock()

        def blast(seed):
            rng = np.random.default_rng(seed)
            with srv.client() as c:
                for _ in range(8):
                    rows = corpus[rng.integers(0, 56, size=8)]
                    r = c.query("l2-basis", rows, k=3)
                    with lock:
                        (oks if r.get("ok") else rejects).append(r)

        threads = [threading.Thread(target=blast, args=(s,))
                   for s in range(12)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120)

        assert rejects, "overload must produce nonzero rejects"
        assert {r["code"] for r in rejects} <= {"overloaded", "queue_full"}
        # explicit backpressure: every retryable reject says when to retry
        assert all(r.get("retry_after_ms", 0) > 0 for r in rejects)
        for r in oks:
            assert len(r["gids"]) == 8 and len(r["gids"][0]) == 3
        with srv.client() as c:
            h = c.health()
            # everything settled after the storm; the quota held
            assert h["tenants"]["l2-basis"]["inflight"] == 0
            assert h["tenants"]["l2-basis"]["queue_depth"] == 0
            st = c.stats()
            wire_rejects = sum(
                v for k, v in st["metrics"].items()
                if k.startswith("frontend_rejects_total")
                and "l2-basis" in k)
            assert wire_rejects == len(rejects)
    finally:
        assert srv.stop() == 0


def test_sigterm_graceful_drain_loses_no_accepted_request():
    """Continuous multi-tenant streams + SIGTERM mid-flight: every stream
    sees clean answers up to exactly one ``shutting_down`` reject, never
    a dropped connection; the drain report proves settled == admitted."""
    srv = _Server("--max-delay-ms", "10")
    try:
        corpora = _corpora(seed=3, n=32)
        with srv.client() as c:
            for t in TENANTS:
                c.insert(t, corpora[t])
                c.query_arrays(t, corpora[t][:4], k=3)    # warm the jit

        lock = threading.Lock()
        stats = {"ok": 0, "drain_rejects": 0}
        errors = []

        def stream(tenant, seed):
            rng = np.random.default_rng(seed)
            try:
                with srv.client() as c:
                    while True:
                        q = corpora[tenant][rng.integers(0, 32, size=4)]
                        r = c.query(tenant, q, k=3)
                        if r.get("ok"):
                            assert len(r["gids"]) == 4
                            with lock:
                                stats["ok"] += 1
                        else:
                            # the drain signal: structured reject, then
                            # the client hangs up -- never a dead socket
                            assert r["code"] == "shutting_down", r
                            with lock:
                                stats["drain_rejects"] += 1
                            return
            except Exception as e:           # noqa: BLE001
                errors.append(f"{tenant}: {e!r}")

        threads = [threading.Thread(target=stream, args=(t, 100 + i))
                   for i, t in enumerate(TENANTS) for _ in range(2)]
        for th in threads:
            th.start()
        time.sleep(1.0)                      # let traffic flow
        srv.proc.send_signal(signal.SIGTERM)
        for th in threads:
            th.join(timeout=60)

        rc = srv.stop()
        assert rc == 0
        assert not errors, errors
        assert stats["ok"] > 0
        assert stats["drain_rejects"] == len(threads)
        drained = [ln for ln in srv.lines if "drained:" in ln]
        assert drained, srv.lines
        m = re.search(r"admitted=(\d+) settled=(\d+) rejected=(\d+) "
                      r"inflight=(\d+)", drained[0])
        assert m is not None, drained[0]
        # the no-lost-request guarantee, from the server's own ledger
        assert m.group(1) == m.group(2)
        assert m.group(4) == "0"
    finally:
        if srv.proc.poll() is None:
            srv.proc.kill()
