"""Sharding-rule unit tests: every spec divides the mesh, FSDP toggles, batch
fallback, cache SP."""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import specs as lspecs
from repro.models import get_model
from repro.sharding import rules

# a 16x16-shaped abstract mesh over 1 real device is enough to EVALUATE the
# rules (no arrays are placed); use a small concrete mesh instead.
pytestmark = []


def _mesh():
    from repro import compat
    return compat.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Duck-typed mesh with production axis sizes for divisibility checks."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch_id,multi", [
    (a, m) for a in ("llama3.2-3b", "qwen2-moe-a2.7b", "arctic-480b",
                     "mistral-large-123b", "mamba2-2.7b", "recurrentgemma-2b",
                     "seamless-m4t-medium", "glm4-9b", "internlm2-20b",
                     "qwen2-vl-2b")
    for m in (False, True)])
def test_param_specs_divide_production_mesh(arch_id, multi):
    """For every arch x mesh, each sharded dim must divide its axis product
    (the jit in_shardings contract)."""
    cfg = get_config(arch_id)
    api = get_model(cfg)
    p_shape = lspecs.params_shape(api)
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16} if multi
                     else {"data": 16, "model": 16})
    spec_tree = rules.param_specs(cfg, p_shape, mesh)

    def check(path, leaf_spec, leaf):
        for dim, ax in zip(leaf.shape, tuple(leaf_spec) + (None,) * 8):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (path, leaf.shape, leaf_spec)

    jax.tree_util.tree_map_with_path(
        lambda pth, sp, lf: check(pth, sp, lf), spec_tree, p_shape,
        is_leaf=lambda x: isinstance(x, P))


def test_fsdp_toggles_data_axis():
    cfg = get_config("internlm2-20b")           # fsdp_params=True
    api = get_model(cfg)
    p_shape = lspecs.params_shape(api)
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = rules.param_specs(cfg, p_shape, mesh)
    wq_spec = spec["layers"]["attn"]["wq"]
    assert "data" in str(wq_spec)
    cfg2 = dataclasses.replace(cfg, fsdp_params=False)
    spec2 = rules.param_specs(cfg2, p_shape, mesh)
    assert "data" not in str(spec2["layers"]["attn"]["wq"])


def test_batch_axis_fallback():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert rules.batch_axis(mesh, 256) == ("pod", "data")
    assert rules.batch_axis(mesh, 32) == ("pod", "data")
    assert rules.batch_axis(mesh, 16) == ("pod",)  # 16 % 32 != 0 -> shrink
    assert rules.batch_axis(mesh, 1) is None


def test_cache_specs_sequence_parallel():
    cfg = get_config("glm4-9b")                 # kv=2 < 16 -> SP on length
    api = get_model(cfg)
    from repro.configs.base import SHAPES
    c_shape = lspecs.cache_shape(api, cfg, SHAPES["decode_32k"])
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = rules.cache_specs(cfg, c_shape, mesh, 128)
    k_spec = spec["k"]
    assert tuple(k_spec)[2] == "model"          # (L, B, T@model, KV, D)
    assert tuple(k_spec)[1] is not None         # batch sharded
