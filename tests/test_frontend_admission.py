"""Injected-clock tests for the front-end's backpressure edges.

Every edge the live-traffic suite can only provoke probabilistically is
pinned here deterministically with a simulated clock: quota exhaustion,
queue-depth caps, deadline-racing admission, unload-while-queued -- each
asserting both the structured rejection *and* the obs counter increment
that makes the edge visible in telemetry.

Also here, because they share the sim clock:

* the dual-clock-mode regression -- the wall-clock pump thread must
  produce **bit-identical** results and the same jit-shape palette as the
  injected-clock manual-pump path (the batching decision logic is shared;
  wall-clock mode only adds scheduling);
* the ``_wait_s`` flush schedule (sleep exactly until the earliest
  pending deadline; 0.0 on a full max chunk; None when idle);
* WAL lifecycle-record round-trip and ``recover`` skipping a cleanly
  unloaded tenant while still rebuilding a crashed one.
"""

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.serve import MicroBatcher, ServableRegistry, ServableSpec
from repro.serve import wal as walmod
from repro.serve.frontend import DRAINING, LOADING, READY, Rejection, \
    RequestGate

N_DIMS = 8


class SimClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


def _gate(clock, **kw):
    kw.setdefault("max_inflight", 2)
    kw.setdefault("queue_depth", 4)
    reg = obs_metrics.MetricsRegistry()
    return RequestGate(clock=clock, metrics=reg, **kw), reg


def _rejects(reg, tenant, reason):
    return reg.value("frontend_rejects_total", tenant=tenant,
                     reason=reason) or 0.0


def _pure_qfn(k=3):
    """Row-content-pure fake engine: output depends only on row values,
    never on position within the padded chunk -- so packing the same
    requests into different chunk sequences must still produce identical
    per-request answers (what the cross-mode bit-identity test needs)."""

    def qfn(buf, kk, n_probes):
        base = np.asarray(np.floor(buf[:, :1] * 1e3), np.int32)
        ids = base + np.arange(kk, dtype=np.int32)
        return ids, ids.astype(np.float32) * 0.25

    return qfn


# -- RequestGate backpressure edges -----------------------------------------


def test_quota_exhaustion_rejects_then_settle_frees_slot():
    clk = SimClock()
    g, reg = _gate(clk, max_inflight=2)
    g.set_state("t", READY)
    a = g.admit("t")
    b = g.admit("t")
    assert not isinstance(a, Rejection) and not isinstance(b, Rejection)
    r = g.admit("t")
    assert isinstance(r, Rejection)
    assert r.code == "overloaded"
    assert r.retry_after_ms == 25.0          # retryable => told when
    assert _rejects(reg, "t", "overloaded") == 1.0
    assert g.inflight("t") == 2              # the reject acquired nothing
    assert g.settle(a) == "ok"
    assert g.inflight("t") == 1
    assert not isinstance(g.admit("t"), Rejection)   # slot freed
    assert reg.value("frontend_inflight", tenant="t") == 2.0


def test_queue_depth_cap_rejects():
    clk = SimClock()
    g, reg = _gate(clk, queue_depth=4)
    g.set_state("t", READY)
    assert not isinstance(g.admit("t", queue_depth=3), Rejection)
    r = g.admit("t", queue_depth=4)
    assert isinstance(r, Rejection) and r.code == "queue_full"
    assert r.retry_after_ms == 25.0
    assert _rejects(reg, "t", "queue_full") == 1.0


def test_lifecycle_state_rejects_each_with_counter():
    clk = SimClock()
    g, reg = _gate(clk)
    g.set_state("ld", LOADING)
    g.set_state("dr", DRAINING)
    for tenant, code, retryable in [("ld", "loading", True),
                                    ("dr", "draining", True),
                                    ("nope", "unknown_tenant", False)]:
        r = g.admit(tenant)
        assert isinstance(r, Rejection) and r.code == code, tenant
        assert (r.retry_after_ms is not None) == retryable
        assert _rejects(reg, tenant, code) == 1.0
    g.set_state("ok", READY)
    g.begin_drain()
    r = g.admit("ok")
    assert isinstance(r, Rejection) and r.code == "shutting_down"
    assert r.retry_after_ms is None          # don't retry a dying process
    assert _rejects(reg, "ok", "shutting_down") == 1.0


def test_deadline_racing_admission():
    clk = SimClock()
    g, reg = _gate(clk)
    g.set_state("t", READY)
    # budget already spent when the request reaches the door
    r = g.admit("t", timeout_ms=0.0)
    assert isinstance(r, Rejection) and r.code == "deadline_expired"
    assert _rejects(reg, "t", "deadline_expired") == 1.0
    # admitted in time, answered too late: settle reports the expiry
    tok = g.admit("t", timeout_ms=5.0)
    assert not isinstance(tok, Rejection)
    clk.advance(0.004)
    early = g.admit("t", timeout_ms=5.0)     # still in budget
    assert not isinstance(early, Rejection)
    assert g.settle(early) == "ok"
    clk.advance(0.002)                       # now 6ms > tok's 5ms budget
    assert g.settle(tok) == "deadline_expired"
    assert reg.value("frontend_deadline_expired_total",
                     tenant="t") == 1.0
    assert g.settle(tok) == "ok"             # double-settle is inert
    assert g.inflight("t") == 0


def test_unload_while_queued_drains_not_drops():
    """Tenant flips to DRAINING with requests already queued: new arrivals
    bounce (and never touch the batcher), the queued ones all resolve."""
    clk = SimClock()
    g, reg = _gate(clk, max_inflight=8)
    g.set_state("t", READY)
    b = MicroBatcher(_pure_qfn(), chunk_sizes=(4, 8), max_delay_ms=50.0,
                     clock=clk, tenant="t",
                     metrics=obs_metrics.MetricsRegistry())
    rng = np.random.default_rng(5)
    toks, futs = [], []
    for _ in range(3):
        tok = g.admit("t", rows=2, queue_depth=b.pending())
        assert not isinstance(tok, Rejection)
        toks.append(tok)
        futs.append(b.submit(
            rng.normal(size=(2, N_DIMS)).astype(np.float32), 3))
    assert b.pending() == 3

    g.set_state("t", DRAINING)
    r = g.admit("t", queue_depth=b.pending())
    assert isinstance(r, Rejection) and r.code == "draining"
    assert _rejects(reg, "t", "draining") == 1.0
    assert b.pending() == 3                  # rejected => never enqueued

    assert b.flush_all() >= 1                # the drain flushes the queue
    for fut in futs:
        ids, dists = fut.result(timeout=5)
        assert ids.shape == (2, 3) and dists.shape == (2, 3)
    for tok in toks:
        assert g.settle(tok, drained=True) == "ok"
    assert reg.value("frontend_drained_requests_total", tenant="t") == 3.0
    assert g.inflight("t") == 0
    assert g.totals() == {"admitted": 3, "rejected": 1, "settled": 3}


# -- batcher clock modes ----------------------------------------------------


def test_wall_clock_mode_bit_identical_to_sim_clock_mode():
    """The wall-clock pump thread must not change *what* is batched, only
    *when* pump runs: identical per-request results and the same shape
    palette as the deterministic injected-clock path."""
    rng = np.random.default_rng(17)
    reqs = [rng.normal(size=(n, N_DIMS)).astype(np.float32)
            for n in (1, 3, 2, 4, 1, 6, 2, 2)]

    def run_sim():
        clk = SimClock()
        b = MicroBatcher(_pure_qfn(), chunk_sizes=(4, 8), max_delay_ms=2.0,
                         clock=clk, metrics=obs_metrics.MetricsRegistry())
        futs = [b.submit(q, 3) for q in reqs]
        clk.advance(0.003)
        b.pump()
        b.flush_all()
        return [f.result(timeout=5) for f in futs], dict(b.shape_counts)

    def run_wall():
        b = MicroBatcher(_pure_qfn(), chunk_sizes=(4, 8), max_delay_ms=2.0,
                         metrics=obs_metrics.MetricsRegistry()).start()
        try:
            futs = [b.submit(q, 3) for q in reqs]
            return ([f.result(timeout=10) for f in futs],
                    dict(b.shape_counts))
        finally:
            b.stop()

    sim1, shapes1 = run_sim()
    sim2, shapes2 = run_sim()
    wall, wshapes = run_wall()
    # sim mode is bit-reproducible run to run (the determinism anchor) --
    # including the dispatched shape sequence, i.e. the jit palette
    assert shapes1 == shapes2
    for (i1, d1), (i2, d2) in zip(sim1, sim2):
        assert (i1 == i2).all() and (d1 == d2).all()
    # wall mode answers bit-identically even though its chunking timing
    # (hence shape_counts) may legitimately differ
    for (ids, dists), (wi, wd) in zip(sim1, wall):
        assert ids.dtype == wi.dtype and dists.dtype == wd.dtype
        assert (ids == wi).all() and (dists == wd).all()
    assert set(c for c, _k, _p in wshapes) <= {4, 8}
    assert set(c for c, _k, _p in shapes1) <= {4, 8}


def test_wait_s_tracks_earliest_deadline():
    clk = SimClock()
    b = MicroBatcher(_pure_qfn(), chunk_sizes=(4, 8), max_delay_ms=10.0,
                     clock=clk, metrics=obs_metrics.MetricsRegistry())
    assert b._wait_s() is None               # idle: park until a submit
    b.submit(np.zeros((2, N_DIMS), np.float32), 3)
    assert b._wait_s() == pytest.approx(0.010)
    clk.advance(0.004)
    assert b._wait_s() == pytest.approx(0.006)
    # a second signature with an earlier obligation wins
    b.submit(np.zeros((1, N_DIMS), np.float32), 5)
    clk.advance(0.005)
    assert b._wait_s() == pytest.approx(0.001)
    clk.advance(0.002)                       # first deadline passed
    assert b._wait_s() == 0.0
    b.pump()
    # a full max chunk flushes immediately regardless of deadline
    b.submit(np.zeros((8, N_DIMS), np.float32), 3)
    assert b._wait_s() == 0.0
    b.flush_all()
    assert b._wait_s() is None


# -- WAL lifecycle records & recovery ---------------------------------------


def test_wal_lifecycle_record_roundtrip(tmp_path):
    path = str(tmp_path / "t.wal")
    wal = walmod.WriteAheadLog(path)
    for state in ("ready", "draining", "unloaded"):
        wal.append(walmod.encode_lifecycle(state))
    wal.close()
    recs, report = walmod.read_wal(path)
    assert not report["truncated"]
    assert [r.op for r in recs] == [walmod.OP_LIFECYCLE] * 3
    assert walmod.OP_NAMES[walmod.OP_LIFECYCLE] == "lifecycle"
    assert [r.value["state"] for r in recs] == \
        ["ready", "draining", "unloaded"]
    assert walmod.read_last_lifecycle(path) == "unloaded"
    with pytest.raises(ValueError):
        walmod.encode_lifecycle("bogus")
    assert walmod.read_last_lifecycle(str(tmp_path / "no.wal")) is None


def _spec(name, **kw):
    base = dict(name=name, n_dims=N_DIMS, r=2.0, log2_buckets=6,
                bucket_capacity=32, segment_capacity=64, insert_chunk=32,
                chunk_sizes=(4, 8), max_delay_ms=2.0)
    base.update(kw)
    return ServableSpec(**base)


def test_recover_skips_cleanly_unloaded_tenant(tmp_path):
    """A clean unload leaves an audit trail but not a resurrectable
    endpoint; a tenant without the trailing "unloaded" record still
    recovers through the lifecycle noise in its WAL."""
    wal_dir = str(tmp_path)
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(12, N_DIMS)).astype(np.float32)

    reg = ServableRegistry(wal_dir=wal_dir)
    for name in ("gone", "kept"):
        reg.register(_spec(name))
        reg.get(name).insert(emb)
        reg.log_lifecycle(name, "ready")
    before = obs_metrics.registry().value(
        "tenant_lifecycle_transitions_total",
        tenant="gone", state="unloaded") or 0.0
    # clean detach of "gone": drain markers then unregister
    reg.log_lifecycle("gone", "draining")
    reg.log_lifecycle("gone", "unloaded")
    assert obs_metrics.registry().value(
        "tenant_lifecycle_transitions_total",
        tenant="gone", state="unloaded") == before + 1.0
    reg.unregister("gone")
    reg.unregister("kept")                   # no lifecycle record: a crash

    reg2 = ServableRegistry(wal_dir=wal_dir)
    reports = reg2.recover(wal_dir=wal_dir)
    assert reg2.names() == ["kept"]          # "gone" stays gone...
    assert reports["gone"]["skipped"] == "unloaded"
    # ...but its WAL survives as an audit trail
    assert walmod.read_last_lifecycle(
        str(tmp_path / "gone.wal")) == "unloaded"
    # "kept" replayed through its non-terminal lifecycle records
    ids, _ = reg2.get("kept").index.query(emb[:3], 2, n_probes=2)
    assert np.asarray(ids).shape == (3, 2)
    assert reg2.get("kept").index.n_live == 12
