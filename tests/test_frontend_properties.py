"""Property tests for the front-end's admission invariants.

Hypothesis drives random interleavings of admit / settle / drain /
lifecycle events against a :class:`RequestGate` (and a gate + sim-clock
:class:`MicroBatcher` pair) and checks, after **every** step:

* ``inflight == admitted - settled`` per tenant, and never negative;
* ``inflight <= max_inflight`` -- the quota is a hard ceiling;
* every attempt is accounted: ``admitted + rejected == attempts``;
* **accepted => answered-or-drained**: every Admission token's future
  resolves (correctly shaped) by the end of the run;
* **rejected => never enqueued**: the batcher's request count only moves
  on admission, so a Rejection leaves no queue entry behind;
* once the process drains, nothing is admitted ever again.

Runs under CI's cpu-1dev property-test leg (hypothesis comes from the
``test`` extra); skips cleanly where hypothesis is absent.
"""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_support import given, settings, st  # noqa: E402

from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.serve import MicroBatcher  # noqa: E402
from repro.serve.frontend import READY, Admission, Rejection, \
    RequestGate  # noqa: E402

N_DIMS = 4
TENANTS = ("a", "b")

# one gate event: (kind, tenant_index, magnitude)
_EVENTS = st.lists(
    st.tuples(
        st.sampled_from(["admit", "settle", "process_drain", "advance"]),
        st.integers(0, len(TENANTS) - 1),
        st.integers(0, 30)),
    min_size=1, max_size=60)


class _ListClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@settings(max_examples=60, deadline=None)
@given(events=_EVENTS, max_inflight=st.integers(1, 4))
def test_gate_ledger_invariants(events, max_inflight):
    clk = _ListClock()
    g = RequestGate(max_inflight=max_inflight, queue_depth=8, clock=clk,
                    metrics=obs_metrics.MetricsRegistry())
    for t in TENANTS:
        g.set_state(t, READY)
    open_toks = {t: [] for t in TENANTS}
    attempts = {t: 0 for t in TENANTS}
    drained = False

    def check():
        for t in TENANTS:
            inflight = g.inflight(t)
            assert inflight == g.admitted[t] - g.settled[t]
            assert 0 <= inflight <= max_inflight
            assert g.admitted[t] + g.rejected[t] == attempts[t]

    for kind, ti, mag in events:
        t = TENANTS[ti]
        if kind == "admit":
            attempts[t] += 1
            out = g.admit(t, rows=1 + mag % 4,
                          timeout_ms=None if mag % 3 else 50.0)
            if isinstance(out, Admission):
                assert not drained, "admitted after process drain"
                open_toks[t].append(out)
            else:
                assert isinstance(out, Rejection)
                assert out.code in ("overloaded", "shutting_down")
        elif kind == "settle" and open_toks[t]:
            g.settle(open_toks[t].pop(mag % len(open_toks[t])))
        elif kind == "process_drain":
            g.begin_drain()
            drained = True
        elif kind == "advance":
            clk.t += mag / 1e3
        check()

    for t in TENANTS:
        for tok in open_toks[t]:
            assert g.settle(tok, drained=drained) in (
                "ok", "deadline_expired")
        assert g.inflight(t) == 0
        assert g.admitted[t] == g.settled[t]


@settings(max_examples=40, deadline=None)
@given(steps=st.lists(
    st.tuples(st.integers(1, 6),       # rows in the request
              st.booleans()),          # pump (advance past deadline) after?
    min_size=1, max_size=25))
def test_accepted_answered_rejected_never_enqueued(steps):
    clk = _ListClock()
    g = RequestGate(max_inflight=3, queue_depth=4, clock=clk,
                    metrics=obs_metrics.MetricsRegistry())
    g.set_state("t", READY)

    def qfn(buf, k, n_probes):
        ids = np.tile(np.arange(k, dtype=np.int32), (buf.shape[0], 1))
        return ids, ids.astype(np.float32)

    b = MicroBatcher(qfn, chunk_sizes=(4, 8), max_delay_ms=5.0, clock=clk,
                     metrics=obs_metrics.MetricsRegistry())
    accepted = []                        # (token, future, rows)
    n_submitted = 0
    rng = np.random.default_rng(0)

    for rows, pump in steps:
        out = g.admit("t", rows=rows, queue_depth=b.pending())
        if isinstance(out, Admission):
            fut = b.submit(rng.normal(size=(rows, N_DIMS)).astype(
                np.float32), 2)
            n_submitted += 1
            accepted.append((out, fut, rows))
        # rejected => never enqueued: the batcher only ever saw admissions
        assert b.n_requests == n_submitted
        if pump:
            clk.t += 0.006
            b.pump()
            for tok, fut, _ in accepted:
                if fut.done() and not tok.settled:
                    g.settle(tok)
        assert g.inflight("t") == len(
            [1 for tok, _f, _r in accepted if not tok.settled])

    b.flush_all()
    for tok, fut, rows in accepted:      # accepted => answered-or-drained
        ids, dists = fut.result(timeout=5)
        assert ids.shape == (rows, 2) and dists.shape == (rows, 2)
        if not tok.settled:
            g.settle(tok, drained=True)
    assert g.inflight("t") == 0
    assert g.totals()["admitted"] == g.totals()["settled"] == len(accepted)
    assert set(c for c, _k, _p in b.shape_counts) <= {4, 8}
