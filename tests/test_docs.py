"""Documentation health: the CI docs job's link check, runnable in tier-1.

The docs job also executes examples/quickstart.py end to end; that is
deliberately CI-only (it builds a 2048-item index), but the link check is
cheap enough to gate every local run too.
"""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_intra_repo_doc_links_resolve():
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_doc_links.py"),
         ROOT], capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stdout + out.stderr
    # the checker actually saw the doc tree (README, docs/, EXPERIMENTS...)
    assert "checked" in out.stdout
    n_files = int(out.stdout.split("checked ")[1].split()[0])
    assert n_files >= 5, out.stdout
