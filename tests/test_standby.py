"""WAL-shipping warm standby: continuous tail, bit-identical promotion.

The standby contract extends invariant 7 (crash recovery is replay of the
durable prefix) with *when* the replay happens: a :class:`WalStandby`
pays the bill continuously while the primary is alive, so ``promote()``
is recovery with almost nothing left to do.  Assertions:

* while tailing, the standby's registry answers **bit-identically** to
  the live primary over the durable prefix (same records, same apply
  order, same invariant-3 structure independence);
* a torn tail (primary mid-append) is retried, never fatal;
* tenants whose log ends in a clean "unloaded" are skipped, exactly as
  ``recover`` skips them -- including an unload that lands *after*
  adoption (re-checked at promotion);
* promotion after a genuine ``kill -9`` of the primary serves the same
  bits as an uninterrupted reference -- unsharded and, in a subprocess,
  sharded over an 8-device host mesh;
* the promoted registry owns the WALs: post-promotion writes append
  where the primary stopped and a later recovery replays them.
"""

import os
import signal
import struct
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serve import ServableRegistry, ServableSpec, WalStandby

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DIMS = 16


def _spec(name="t", p=2.0, emb="basis"):
    return ServableSpec(name=name, n_dims=N_DIMS, p=p, r=2.0, embedder=emb,
                        log2_buckets=8, bucket_capacity=64,
                        segment_capacity=64, insert_chunk=32,
                        chunk_sizes=(8, 32))


def _data(n, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=(n, N_DIMS)) *
            scale).astype(np.float32)


def _arrays(pair):
    i, d = pair
    return np.asarray(i), np.asarray(d)


def _primary(wal_dir, names=("t",)):
    reg = ServableRegistry(wal_dir=wal_dir, fsync_every=1)
    for name in names:
        reg.register(_spec(name))
    return reg


def test_standby_tails_and_promotes_bit_identical(tmp_path):
    wal_dir = str(tmp_path / "wal")
    prim = _primary(wal_dir)
    sb = WalStandby(wal_dir)

    q = _data(9, seed=9, scale=0.9)
    sv = prim.get("t")
    for seed in (1, 2, 3):
        g = sv.insert(_data(40, seed=seed))
        sv.delete(g[::6])
        if seed == 2:
            sv.maintenance.compact()
        out = sb.poll_once()
        assert out["t"]["lag_bytes"] == 0
        want_i, want_d = _arrays(sv.index.query(q, 10, n_probes=4))
        got_i, got_d = _arrays(
            sb.registry.get("t").index.query(q, 10, n_probes=4))
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_d, want_d)

    # lag observable mid-stream: durable-but-unreplayed bytes
    sv.insert(_data(20, seed=4))
    assert sb.lag()["t"] > 0
    sb.poll_once()
    assert sb.lag()["t"] == 0

    reports = sb.promote()
    assert reports["t"]["applied"] == 0          # nothing left to replay
    assert sb.promote() == {}                    # idempotent

    # the promoted registry owns the log: new writes append + recover
    psv = sb.registry.get("t")
    want_i, want_d = _arrays(sv.index.query(q, 10, n_probes=4))
    got_i, got_d = _arrays(psv.index.query(q, 10, n_probes=4))
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_d, want_d)
    psv.index.insert(_data(15, seed=5))
    reg3 = ServableRegistry()
    reg3.recover(wal_dir=wal_dir)
    np.testing.assert_array_equal(
        np.asarray(reg3.get("t").index.query(q, 10, n_probes=4)[0]),
        np.asarray(psv.index.query(q, 10, n_probes=4)[0]))


def test_standby_torn_tail_retries(tmp_path):
    wal_dir = str(tmp_path / "wal")
    prim = _primary(wal_dir)
    sv = prim.get("t")
    sv.insert(_data(30, seed=1))
    sb = WalStandby(wal_dir)
    sb.poll_once()

    # simulate the primary mid-append: a torn frame at the tail
    path = os.path.join(wal_dir, "t.wal")
    with open(path, "ab") as f:
        f.write(struct.pack("<I", 1000) + b"\x00" * 7)
    out = sb.poll_once()                         # stops before the tear
    assert out["t"]["applied"] == 0
    torn_lag = out["t"]["lag_bytes"]
    assert torn_lag > 0

    # "more bytes land": restore a clean tail by truncating the tear,
    # then a real append -- the cursor picks up right where it stopped
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(size - 11)
    sv.insert(_data(10, seed=2))
    out = sb.poll_once()
    assert out["t"]["applied"] > 0 and out["t"]["lag_bytes"] == 0
    q = _data(5, seed=9, scale=0.9)
    np.testing.assert_array_equal(
        np.asarray(sb.registry.get("t").index.query(q, 10, n_probes=4)[0]),
        np.asarray(sv.index.query(q, 10, n_probes=4)[0]))


def test_standby_skips_unloaded_tenants(tmp_path):
    wal_dir = str(tmp_path / "wal")
    prim = _primary(wal_dir, names=("keep", "gone", "late"))
    for name in ("keep", "gone", "late"):
        prim.get(name).insert(_data(30, seed=1))
    # "gone" unloads BEFORE the standby ever sees it
    prim.log_lifecycle("gone", "unloaded")
    prim.unregister("gone")

    sb = WalStandby(wal_dir)
    out = sb.poll_once()
    assert sorted(out) == ["keep", "late"]
    assert sorted(sb.registry.names()) == ["keep", "late"]

    # "late" unloads AFTER adoption: replays as a lifecycle no-op, then
    # promotion drops it (recovery's trailing-unloaded rule)
    prim.log_lifecycle("late", "unloaded")
    prim.unregister("late")
    sb.poll_once()
    reports = sb.promote()
    assert reports["late"] == {"skipped": "unloaded"}
    assert sb.registry.names() == ["keep"]


def test_standby_tailer_thread_runs(tmp_path):
    import time
    wal_dir = str(tmp_path / "wal")
    prim = _primary(wal_dir)
    sb = WalStandby(wal_dir, poll_interval_s=0.01)
    sb.start()
    try:
        prim.get("t").insert(_data(25, seed=1))
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            lag = sb.lag()
            if lag.get("t") == 0:
                break
            time.sleep(0.01)
        assert sb.lag().get("t") == 0
    finally:
        sb.stop()
    assert sb.registry.get("t").index.n_live == 25


# ---------------------------------------------------------------------------
# failover after kill -9, including the 8-device mesh leg
# ---------------------------------------------------------------------------


def _env(n_devices=1):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={n_devices}")
    return env


def _run(code, n_devices=1, timeout=560):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=_env(n_devices))


_COMMON = """
    import numpy as np
    from repro.serve import ServableRegistry, ServableSpec

    def spec():
        return ServableSpec(
            name="t", n_dims=16, p=2.0, r=2.0, embedder="basis",
            log2_buckets=8, bucket_capacity=64, segment_capacity=64,
            insert_chunk=32, chunk_sizes=(8, 32))

    def queries():
        return (np.random.default_rng(1).normal(size=(9, 16)) *
                0.9).astype(np.float32)
"""

_CRASH = _COMMON + """
    import sys
    from repro.serve import faults

    faults.install(faults.FaultPlan(
        faults.FaultSpec("wal.appended", nth={nth}, action="kill")))
    reg = ServableRegistry(wal_dir={wal!r}, fsync_every=1)
    sv = reg.register(spec())
    rng = np.random.default_rng(0)
    for step in range(10):
        g = sv.insert(rng.normal(size=(25, 16)).astype(np.float32))
        if step % 2 == 1:
            sv.delete(g[:5])
        if step % 4 == 3:
            sv.maintenance.compact()
    print("SURVIVED")
    sys.exit(3)
"""

_PROMOTE = _COMMON + """
    import os
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import WalStandby
    from repro.serve.registry import _spec_from_manifest
    from repro.serve.wal import read_spec

    WAL, N_DEV = {wal!r}, {n_dev}
    mesh = make_serve_mesh(N_DEV) if N_DEV > 1 else None
    sb = WalStandby(WAL, mesh=mesh)
    sb.poll_once()                 # warm: replay while "primary" is down
    reports = sb.promote()
    assert "t" in reports, reports

    # reference = uninterrupted run over the durable prefix
    wpath = os.path.join(WAL, "t.wal")
    ref = ServableRegistry()
    rsv = ref.register(_spec_from_manifest(read_spec(wpath)))
    rsv.index.replay(wpath)

    qs = queries()
    wi, wd = map(np.asarray, rsv.index.query(qs, 10, n_probes=4))
    gi, gd = map(np.asarray,
                 sb.registry.get("t").index.query(qs, 10, n_probes=4))
    assert np.array_equal(gi, wi) and np.array_equal(gd, wd)

    # promoted registry keeps logging: append, then a fresh recovery
    # over the same dir sees the post-failover writes
    sb.registry.get("t").index.insert(
        np.random.default_rng(7).normal(size=(10, 16)).astype(np.float32))
    reg2 = ServableRegistry()
    reg2.recover(wal_dir=WAL)
    gi2 = np.asarray(reg2.get("t").index.query(qs, 10, n_probes=4)[0])
    gi3 = np.asarray(
        sb.registry.get("t").index.query(qs, 10, n_probes=4)[0])
    assert np.array_equal(gi2, gi3)
    print("PROMOTE_OK")
"""


@pytest.mark.parametrize("n_dev", [1, 8], ids=["unsharded", "mesh8"])
def test_kill9_primary_standby_promotes_bit_identical(tmp_path, n_dev):
    wal_dir = str(tmp_path / "wal")
    crash = _run(_CRASH.format(wal=wal_dir, nth=12))
    assert crash.returncode == -signal.SIGKILL, (
        f"expected SIGKILL, got rc={crash.returncode}\n"
        f"stdout: {crash.stdout[-1500:]}\nstderr: {crash.stderr[-1500:]}")

    rec = _run(_PROMOTE.format(wal=wal_dir, n_dev=n_dev), n_devices=n_dev)
    assert rec.returncode == 0, (
        f"promotion failed\nstdout: {rec.stdout[-1500:]}\n"
        f"stderr: {rec.stderr[-3000:]}")
    assert "PROMOTE_OK" in rec.stdout
